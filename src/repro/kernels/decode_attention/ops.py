"""Jit'd public wrapper for the decode-attention Pallas kernel.

``block_k=None`` consults the autotune cache (``repro.perf.autotune``)
for the best-known tiling of this (shape-class, dtype, backend); an empty
cache falls back to the historical 256 default.  Explicit kwargs win.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention_fwd
from repro.kernels.decode_attention.paged_decode_attention import \
    paged_decode_attention_fwd
from repro.perf import autotune


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


DEFAULT_BLOCK_K = autotune.DEFAULTS["decode_attention"]["block_k"]
DEFAULT_PAGE_SIZE = autotune.DEFAULTS["paged_decode_attention"]["page_size"]


def _resolve_block_k(block_k: Optional[int], dtype, BKV: int, G: int,
                     hd: int, S: int) -> int:
    if block_k is not None:
        return block_k
    cfg = autotune.lookup("decode_attention", dtype, BKV=BKV, G=G, hd=hd, S=S)
    return cfg["block_k"] if cfg else DEFAULT_BLOCK_K


def decode_attention(
    q: jax.Array,        # (B, H, hd)
    k_cache: jax.Array,  # (B, S, KV, hd)
    v_cache: jax.Array,  # (B, S, KV, hd)
    pos,                 # scalar int32
    *,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    block_k = _resolve_block_k(block_k, q.dtype,
                               q.shape[0] * k_cache.shape[2],
                               q.shape[1] // k_cache.shape[2], q.shape[2],
                               k_cache.shape[1])
    return _decode_attention(q, k_cache, v_cache, pos, window=window,
                             logit_cap=logit_cap, block_k=block_k,
                             interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("window", "logit_cap", "block_k", "interpret"))
def _decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos,
    *,
    window: Optional[int],
    logit_cap: Optional[float],
    block_k: int,
    interpret: Optional[bool],
) -> jax.Array:
    if interpret is None:
        interpret = _on_cpu()
    B, H, hd = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV

    block_k = min(block_k, S)
    pad = (-S) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = k_cache.shape[1]

    q3 = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    k3 = k_cache.transpose(0, 2, 1, 3).reshape(B * KV, Sp, hd)
    v3 = v_cache.transpose(0, 2, 1, 3).reshape(B * KV, Sp, hd)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    out = decode_attention_fwd(q3, k3, v3, pos_arr, window=window,
                               logit_cap=logit_cap, block_k=block_k,
                               interpret=interpret)
    return out.reshape(B, KV, G, hd).reshape(B, H, hd)


def decode_attention_kvmajor(
    q: jax.Array,        # (B, H, hd)
    k_cache: jax.Array,  # (B, KV, S, hd) — the model's attention-native layout
    v_cache: jax.Array,
    pos,
    *,
    window=None,
    logit_cap=None,
    block_k: Optional[int] = None,
    interpret=None,
):
    """Like decode_attention but takes the (B, KV, S, hd) cache layout the
    model uses — a pure reshape, no transpose."""
    block_k = _resolve_block_k(block_k, q.dtype,
                               q.shape[0] * k_cache.shape[1],
                               q.shape[1] // k_cache.shape[1], q.shape[2],
                               k_cache.shape[2])
    return _decode_attention_kvmajor(q, k_cache, v_cache, pos, window=window,
                                     logit_cap=logit_cap, block_k=block_k,
                                     interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("window", "logit_cap", "block_k", "interpret"))
def _decode_attention_kvmajor(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos,
    *,
    window,
    logit_cap,
    block_k: int,
    interpret,
):
    if interpret is None:
        interpret = _on_cpu()
    B, H, hd = q.shape
    _, KV, S, _ = k_cache.shape
    G = H // KV
    block_k = min(block_k, S)
    pad = (-S) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Sp = k_cache.shape[2]
    q3 = q.reshape(B * KV, G, hd)
    k3 = k_cache.reshape(B * KV, Sp, hd)
    v3 = v_cache.reshape(B * KV, Sp, hd)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)
    out = decode_attention_fwd(q3, k3, v3, pos_arr, window=window,
                               logit_cap=logit_cap, block_k=block_k,
                               interpret=interpret)
    return out.reshape(B, H, hd)


def resolve_page_size(dtype, *, B: int, H: int, KV: int, hd: int,
                      seq_budget: int,
                      page_size: Optional[int] = None) -> int:
    """Page size for a paged KV cache serving this geometry.

    Unlike ``block_k`` (a tiling knob over fixed inputs), the page size
    changes the cache LAYOUT, so it is resolved once at cache-construction
    time: explicit wins, else the autotune cache's best-known page size for
    the shape class, else the historical default."""
    if page_size is not None:
        return page_size
    cfg = autotune.lookup("paged_decode_attention", dtype, BKV=B * KV,
                          G=H // KV, hd=hd, S=seq_budget)
    return cfg["page_size"] if cfg else DEFAULT_PAGE_SIZE


def paged_decode_attention(
    q: jax.Array,            # (B, H, hd) — one new token per live slot
    k_pages: jax.Array,      # (P, page_size, KV, hd) — shared page pool
    v_pages: jax.Array,      # (P, page_size, KV, hd)
    kv_lens,                 # (B,) int32 — valid cache length per slot
    block_tables,            # (B, ns) int32 — physical page ids per slot
    *,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Decode attention over a PAGED ragged-batch KV cache (the token
    engine's layout).  Slot ``b`` attends over ``kv_lens[b]`` keys read
    from pages ``block_tables[b, :]`` of the shared pool; slots at
    different sequence positions share one batch, and a freed slot
    (``kv_lens[b] == 0``) returns zeros.  Validated against
    ``ref.decode_attention_ref_ragged``."""
    return _paged_decode_attention(q, k_pages, v_pages, kv_lens,
                                   block_tables, window=window,
                                   logit_cap=logit_cap, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("window", "logit_cap", "interpret"))
def _paged_decode_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    kv_lens,
    block_tables,
    *,
    window: Optional[int],
    logit_cap: Optional[float],
    interpret: Optional[bool],
) -> jax.Array:
    if interpret is None:
        interpret = _on_cpu()
    B, H, hd = q.shape
    P, psz, KV, _ = k_pages.shape
    G = H // KV
    ns = block_tables.shape[1]

    lens = jnp.asarray(kv_lens, jnp.int32)
    tbl = jnp.asarray(block_tables, jnp.int32)
    # table entries past a slot's length are never read (pl.when skips the
    # page) but their index still reaches the BlockSpec index_map — clamp
    # padding entries into the pool so the prefetch address is always valid
    pages_needed = (lens[:, None] + psz - 1) // psz
    tbl = jnp.where(jnp.arange(ns)[None, :] < pages_needed, tbl, 0)

    # fold KV heads into the page axis (same fold as the dense wrapper):
    # pool page p of kv head k lives at row k*P + p
    q3 = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    k3 = k_pages.transpose(2, 0, 1, 3).reshape(KV * P, psz, hd)
    v3 = v_pages.transpose(2, 0, 1, 3).reshape(KV * P, psz, hd)
    tbl3 = (tbl[:, None, :]
            + (jnp.arange(KV, dtype=jnp.int32) * P)[None, :, None])
    tbl3 = tbl3.reshape(B * KV, ns)
    lens3 = jnp.broadcast_to(lens[:, None], (B, KV)).reshape(B * KV)

    out = paged_decode_attention_fwd(q3, k3, v3, lens3, tbl3, window=window,
                                     logit_cap=logit_cap, interpret=interpret)
    return out.reshape(B, KV, G, hd).reshape(B, H, hd)
