"""Jit'd public wrapper for the decode-attention Pallas kernel.

``block_k=None`` consults the autotune cache (``repro.perf.autotune``)
for the best-known tiling of this (shape-class, dtype, backend); an empty
cache falls back to the historical 256 default.  Explicit kwargs win.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention_fwd
from repro.perf import autotune


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


DEFAULT_BLOCK_K = autotune.DEFAULTS["decode_attention"]["block_k"]


def _resolve_block_k(block_k: Optional[int], dtype, BKV: int, G: int,
                     hd: int, S: int) -> int:
    if block_k is not None:
        return block_k
    cfg = autotune.lookup("decode_attention", dtype, BKV=BKV, G=G, hd=hd, S=S)
    return cfg["block_k"] if cfg else DEFAULT_BLOCK_K


def decode_attention(
    q: jax.Array,        # (B, H, hd)
    k_cache: jax.Array,  # (B, S, KV, hd)
    v_cache: jax.Array,  # (B, S, KV, hd)
    pos,                 # scalar int32
    *,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    block_k = _resolve_block_k(block_k, q.dtype,
                               q.shape[0] * k_cache.shape[2],
                               q.shape[1] // k_cache.shape[2], q.shape[2],
                               k_cache.shape[1])
    return _decode_attention(q, k_cache, v_cache, pos, window=window,
                             logit_cap=logit_cap, block_k=block_k,
                             interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("window", "logit_cap", "block_k", "interpret"))
def _decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos,
    *,
    window: Optional[int],
    logit_cap: Optional[float],
    block_k: int,
    interpret: Optional[bool],
) -> jax.Array:
    if interpret is None:
        interpret = _on_cpu()
    B, H, hd = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV

    block_k = min(block_k, S)
    pad = (-S) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = k_cache.shape[1]

    q3 = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    k3 = k_cache.transpose(0, 2, 1, 3).reshape(B * KV, Sp, hd)
    v3 = v_cache.transpose(0, 2, 1, 3).reshape(B * KV, Sp, hd)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    out = decode_attention_fwd(q3, k3, v3, pos_arr, window=window,
                               logit_cap=logit_cap, block_k=block_k,
                               interpret=interpret)
    return out.reshape(B, KV, G, hd).reshape(B, H, hd)


def decode_attention_kvmajor(
    q: jax.Array,        # (B, H, hd)
    k_cache: jax.Array,  # (B, KV, S, hd) — the model's attention-native layout
    v_cache: jax.Array,
    pos,
    *,
    window=None,
    logit_cap=None,
    block_k: Optional[int] = None,
    interpret=None,
):
    """Like decode_attention but takes the (B, KV, S, hd) cache layout the
    model uses — a pure reshape, no transpose."""
    block_k = _resolve_block_k(block_k, q.dtype,
                               q.shape[0] * k_cache.shape[1],
                               q.shape[1] // k_cache.shape[1], q.shape[2],
                               k_cache.shape[2])
    return _decode_attention_kvmajor(q, k_cache, v_cache, pos, window=window,
                                     logit_cap=logit_cap, block_k=block_k,
                                     interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("window", "logit_cap", "block_k", "interpret"))
def _decode_attention_kvmajor(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos,
    *,
    window,
    logit_cap,
    block_k: int,
    interpret,
):
    if interpret is None:
        interpret = _on_cpu()
    B, H, hd = q.shape
    _, KV, S, _ = k_cache.shape
    G = H // KV
    block_k = min(block_k, S)
    pad = (-S) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Sp = k_cache.shape[2]
    q3 = q.reshape(B * KV, G, hd)
    k3 = k_cache.reshape(B * KV, Sp, hd)
    v3 = v_cache.reshape(B * KV, Sp, hd)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)
    out = decode_attention_fwd(q3, k3, v3, pos_arr, window=window,
                               logit_cap=logit_cap, block_k=block_k,
                               interpret=interpret)
    return out.reshape(B, H, hd)
