"""Jit'd public wrapper for the decode-attention Pallas kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention_fwd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(
    jax.jit,
    static_argnames=("window", "logit_cap", "block_k", "interpret"))
def decode_attention(
    q: jax.Array,        # (B, H, hd)
    k_cache: jax.Array,  # (B, S, KV, hd)
    v_cache: jax.Array,  # (B, S, KV, hd)
    pos,                 # scalar int32
    *,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    block_k: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = _on_cpu()
    B, H, hd = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV

    block_k = min(block_k, S)
    pad = (-S) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = k_cache.shape[1]

    q3 = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    k3 = k_cache.transpose(0, 2, 1, 3).reshape(B * KV, Sp, hd)
    v3 = v_cache.transpose(0, 2, 1, 3).reshape(B * KV, Sp, hd)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    out = decode_attention_fwd(q3, k3, v3, pos_arr, window=window,
                               logit_cap=logit_cap, block_k=block_k,
                               interpret=interpret)
    return out.reshape(B, KV, G, hd).reshape(B, H, hd)


@functools.partial(
    jax.jit, static_argnames=("window", "logit_cap", "block_k", "interpret"))
def decode_attention_kvmajor(
    q: jax.Array,        # (B, H, hd)
    k_cache: jax.Array,  # (B, KV, S, hd) — the model's attention-native layout
    v_cache: jax.Array,
    pos,
    *,
    window=None,
    logit_cap=None,
    block_k: int = 256,
    interpret=None,
):
    """Like decode_attention but takes the (B, KV, S, hd) cache layout the
    model uses — a pure reshape, no transpose."""
    if interpret is None:
        interpret = _on_cpu()
    B, H, hd = q.shape
    _, KV, S, _ = k_cache.shape
    G = H // KV
    block_k = min(block_k, S)
    pad = (-S) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Sp = k_cache.shape[2]
    q3 = q.reshape(B * KV, G, hd)
    k3 = k_cache.reshape(B * KV, Sp, hd)
    v3 = v_cache.reshape(B * KV, Sp, hd)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)
    out = decode_attention_fwd(q3, k3, v3, pos_arr, window=window,
                               logit_cap=logit_cap, block_k=block_k,
                               interpret=interpret)
    return out.reshape(B, H, hd)
