"""Pallas TPU paged decode-attention kernel: ragged slots vs a paged KV pool.

Continuous batching keeps each live decode slot's KV cache in fixed-size
PAGES scattered across one shared physical pool instead of a contiguous
per-slot region: slot ``b``'s logical key axis is the concatenation
``pages[tbl[b, 0]], pages[tbl[b, 1]], ...`` truncated at ``kv_lens[b]``.
Admitting a request claims free pages, evict-on-EOS returns them — no
copying, no per-slot max-length reservation.

Grid = (B*KV, ns) with one PAGE per grid step.  The per-slot lengths and
the block table ride scalar prefetch (``num_scalar_prefetch=2``), so the
page index feeds the k/v BlockSpec ``index_map`` directly — the DMA
fetches exactly the physical pages the table names — and pages entirely
beyond a slot's length are skipped with ``pl.when``: a short slot in a
ragged batch costs HBM reads proportional to ITS length, not the batch
maximum.  The online-softmax accumulation in VMEM scratch is exactly the
dense decode kernel's."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM

    def _compiler_params():
        try:
            return pltpu.CompilerParams(
                dimension_semantics=("parallel", "arbitrary"))
        except Exception:
            return None
except Exception:  # pragma: no cover
    _VMEM = None

    def _compiler_params():
        return None

NEG_INF = -2.0 ** 30


def _paged_kernel(lens_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, page_size: int, ns: int,
                  window: Optional[int], logit_cap: Optional[float],
                  scale: float):
    b = pl.program_id(0)
    ji = pl.program_id(1)
    k0 = ji * page_size
    length = lens_ref[b]            # valid keys for this slot: kpos < length

    @pl.when(ji == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip pages entirely past this slot's length (or fully outside the
    # sliding window around its newest token, pos = length - 1)
    run = k0 < length
    if window is not None:
        run = jnp.logical_and(run, k0 + page_size > length - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0] * scale                                  # (G, hd)
        k = k_ref[0]                                          # (psz, hd)
        v = v_ref[0]
        s = lax.dot_general(q.astype(jnp.float32), k.astype(jnp.float32),
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, psz)
        if logit_cap is not None:
            s = logit_cap * jnp.tanh(s / logit_cap)
        kpos = k0 + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < length
        if window is not None:
            mask = mask & (kpos > length - 1 - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            l_ref[:, :1] * corr + p.sum(axis=1, keepdims=True), l_ref.shape)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        pv = lax.dot_general(p, v.astype(jnp.float32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(ji == ns - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention_fwd(
    q: jax.Array,        # (BKV, G, hd)
    k_pages: jax.Array,  # (P, page_size, hd) — shared physical page pool
    v_pages: jax.Array,  # (P, page_size, hd)
    kv_lens: jax.Array,  # (BKV,) int32
    block_tables: jax.Array,  # (BKV, ns) int32 — physical page per slot/step
    *,
    window: Optional[int],
    logit_cap: Optional[float],
    interpret: bool,
) -> jax.Array:
    BKV, G, hd = q.shape
    page_size = k_pages.shape[1]
    ns = block_tables.shape[1]
    scale = hd ** -0.5

    kernel = functools.partial(_paged_kernel, page_size=page_size, ns=ns,
                               window=window, logit_cap=logit_cap,
                               scale=scale)
    if _VMEM is not None:
        scratch = [
            _VMEM((G, 128), jnp.float32),
            _VMEM((G, 128), jnp.float32),
            _VMEM((G, hd), jnp.float32),
        ]
        # the index_map consults the prefetched block table: grid step
        # (b, j) DMAs physical page tbl[b, j].  Entries past a slot's
        # length are skipped by pl.when but still indexed — the wrapper
        # clamps them into range.
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(BKV, ns),
            in_specs=[
                pl.BlockSpec((1, G, hd),
                             lambda b, j, lens_ref, tbl_ref: (b, 0, 0)),
                pl.BlockSpec((1, page_size, hd),
                             lambda b, j, lens_ref, tbl_ref:
                             (tbl_ref[b, j], 0, 0)),
                pl.BlockSpec((1, page_size, hd),
                             lambda b, j, lens_ref, tbl_ref:
                             (tbl_ref[b, j], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, G, hd),
                                   lambda b, j, lens_ref, tbl_ref: (b, 0, 0)),
            scratch_shapes=scratch,
        )
        cp = _compiler_params()
        kwargs = {"compiler_params": cp} if cp is not None else {}
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((BKV, G, hd), q.dtype),
            interpret=interpret,
            **kwargs,
        )(kv_lens, block_tables, q, k_pages, v_pages)
    raise RuntimeError("pallas tpu backend unavailable")  # pragma: no cover
