"""Pure-jnp oracle for single-token GQA decode attention."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def decode_attention_ref(
    q: jax.Array,        # (B, H, hd)
    k_cache: jax.Array,  # (B, S, KV, hd)
    v_cache: jax.Array,  # (B, S, KV, hd)
    pos,                 # scalar int32 — new token index; cache valid [0, pos]
    *,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
) -> jax.Array:
    B, H, hd = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = hd ** -0.5
    qh = q.reshape(B, KV, G, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bskd->bkgs", qh, k_cache.astype(jnp.float32))
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)
    kpos = jnp.arange(S)
    mask = kpos <= pos
    if window is not None:
        mask = mask & (kpos > pos - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def decode_attention_ref_ragged(
    q: jax.Array,        # (B, H, hd)
    k_cache: jax.Array,  # (B, S, KV, hd)
    v_cache: jax.Array,  # (B, S, KV, hd)
    lens,                # (B,) int32 — valid cache entries per slot: [0, lens)
    *,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
) -> jax.Array:
    """Ragged-batch oracle: each slot attends over its OWN cache length.

    This is the continuous-batching shape — live decode slots at different
    sequence positions share one batch — and the reference the paged-KV
    kernel is validated against.  A slot with ``lens[b] == 0`` (a freed /
    padding slot) returns zeros, matching the kernel's empty accumulator."""
    B, H, hd = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = hd ** -0.5
    lens = jnp.asarray(lens, jnp.int32)
    qh = q.reshape(B, KV, G, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bskd->bkgs", qh, k_cache.astype(jnp.float32))
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)
    kpos = jnp.arange(S)[None, :]                      # (1, S)
    mask = kpos < lens[:, None]                        # (B, S)
    if window is not None:
        mask = mask & (kpos > lens[:, None] - 1 - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    out = jnp.where(lens[:, None, None, None] > 0, out, 0.0)
    return out.reshape(B, H, hd).astype(q.dtype)
