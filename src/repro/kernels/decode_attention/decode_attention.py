"""Pallas TPU decode-attention kernel: one new token vs a KV cache.

Grid = (B*KV, ns); the key axis is blocked (block_k) and accumulated with an
online softmax in VMEM scratch.  K tiles entirely beyond ``pos`` (or outside
the sliding window) are skipped with ``pl.when`` on the *traced* position —
on TPU this saves HBM reads of the dead cache region.  The GQA group axis
forms the matmul rows.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM

    def _compiler_params():
        try:
            return pltpu.CompilerParams(
                dimension_semantics=("parallel", "arbitrary"))
        except Exception:
            return None
except Exception:  # pragma: no cover
    _VMEM = None

    def _compiler_params():
        return None

NEG_INF = -2.0 ** 30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_k: int, ns: int, window: Optional[int],
            logit_cap: Optional[float], scale: float):
    ki = pl.program_id(1)
    k0 = ki * block_k
    pos = pos_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = k0 <= pos
    if window is not None:
        run = jnp.logical_and(run, k0 + block_k - 1 > pos - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0] * scale                                  # (G, hd)
        k = k_ref[0]                                          # (bk, hd)
        v = v_ref[0]
        s = lax.dot_general(q.astype(jnp.float32), k.astype(jnp.float32),
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bk)
        if logit_cap is not None:
            s = logit_cap * jnp.tanh(s / logit_cap)
        kpos = k0 + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= pos
        if window is not None:
            mask = mask & (kpos > pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            l_ref[:, :1] * corr + p.sum(axis=1, keepdims=True), l_ref.shape)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        pv = lax.dot_general(p, v.astype(jnp.float32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(ki == ns - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_fwd(
    q: jax.Array,        # (BKV, G, hd)
    k: jax.Array,        # (BKV, S, hd)
    v: jax.Array,        # (BKV, S, hd)
    pos: jax.Array,      # (1,) int32
    *,
    window: Optional[int],
    logit_cap: Optional[float],
    block_k: int,
    interpret: bool,
) -> jax.Array:
    BKV, G, hd = q.shape
    S = k.shape[1]
    assert S % block_k == 0, (S, block_k)
    ns = S // block_k
    scale = hd ** -0.5

    kernel = functools.partial(_kernel, block_k=block_k, ns=ns, window=window,
                               logit_cap=logit_cap, scale=scale)
    if _VMEM is not None:
        scratch = [
            _VMEM((G, 128), jnp.float32),
            _VMEM((G, 128), jnp.float32),
            _VMEM((G, hd), jnp.float32),
        ]
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(BKV, ns),
            in_specs=[
                pl.BlockSpec((1, G, hd), lambda b, j, pos_ref: (b, 0, 0)),
                pl.BlockSpec((1, block_k, hd), lambda b, j, pos_ref: (b, j, 0)),
                pl.BlockSpec((1, block_k, hd), lambda b, j, pos_ref: (b, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, G, hd), lambda b, j, pos_ref: (b, 0, 0)),
            scratch_shapes=scratch,
        )
        cp = _compiler_params()
        kwargs = {"compiler_params": cp} if cp is not None else {}
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((BKV, G, hd), q.dtype),
            interpret=interpret,
            **kwargs,
        )(pos, q, k, v)
    raise RuntimeError("pallas tpu backend unavailable")  # pragma: no cover
