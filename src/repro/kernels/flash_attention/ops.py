"""Jit'd public wrapper for the flash-attention Pallas kernel.

Accepts model-layout tensors (B, T, H, hd) / (B, S, KV, hd), handles GQA
folding, padding to block multiples, and interpret-mode selection (CPU).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_cap", "q_offset",
                     "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,                # (B, Tq, H, hd)
    k: jax.Array,                # (B, Tk, KV, hd)
    v: jax.Array,                # (B, Tk, KV, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = _on_cpu()
    B, Tq, H, hd = q.shape
    _, Tk, KV, _ = k.shape
    G = H // KV

    block_q = min(block_q, Tq) if Tq >= 8 else Tq
    block_k = min(block_k, Tk) if Tk >= 8 else Tk

    pad_q = (-Tq) % block_q
    pad_k = (-Tk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    Tqp, Tkp = qp.shape[1], kp.shape[1]

    # (B, T, KV, G, hd) -> (B*KV, G, T, hd)
    q4 = qp.reshape(B, Tqp, KV, G, hd).transpose(0, 2, 3, 1, 4).reshape(
        B * KV, G, Tqp, hd)
    k3 = kp.transpose(0, 2, 1, 3).reshape(B * KV, Tkp, hd)
    v3 = vp.transpose(0, 2, 1, 3).reshape(B * KV, Tkp, hd)

    # Padded K positions are masked: causal masking handles the q-pad rows;
    # for k-pad we rely on kpos > q_max when causal.  For non-causal inputs we
    # must mask explicitly — emulate by setting window/causal masks upstream;
    # here pad keys get position >= Tk and a -inf via explicit valid check:
    if pad_k and not causal:
        # cheap fallback: zero-pad keys produce uniform logits; mask by
        # appending a window over valid length instead — handled by padding
        # with NEG values in k is incorrect, so use causal=False + valid mask
        # path in the reference. For simplicity, require no k-pad when
        # non-causal (callers pass block-divisible encoder lengths).
        raise ValueError("non-causal flash kernel requires Tk % block_k == 0")

    out = flash_attention_fwd(
        q4, k3, v3, causal=causal, window=window, logit_cap=logit_cap,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=interpret)
    out = out.reshape(B, KV, G, Tqp, hd).transpose(0, 3, 1, 2, 4).reshape(
        B, Tqp, H, hd)
    return out[:, :Tq]
