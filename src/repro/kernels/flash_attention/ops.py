"""Jit'd public wrapper for the flash-attention Pallas kernel.

Accepts model-layout tensors (B, T, H, hd) / (B, S, KV, hd), handles GQA
folding, padding to block multiples (pad keys are masked via a static
``kv_len``, so non-divisible lengths work for causal AND non-causal
attention), and interpret-mode selection (CPU).

Tile sizes: explicit ``block_q``/``block_k`` kwargs always win; when left
None the autotune cache (``repro.perf.autotune``) supplies the best-known
tiling for this (shape-class, dtype, backend), falling back to the
historical 128/128 defaults on a cache miss.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.perf import autotune


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


DEFAULT_BLOCK_Q = autotune.DEFAULTS["flash_attention"]["block_q"]
DEFAULT_BLOCK_K = autotune.DEFAULTS["flash_attention"]["block_k"]


def flash_attention(
    q: jax.Array,                # (B, Tq, H, hd)
    k: jax.Array,                # (B, Tk, KV, hd)
    v: jax.Array,                # (B, Tk, KV, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    q_offset: int = 0,
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if block_q is None or block_k is None:
        cfg = autotune.lookup(
            "flash_attention", q.dtype, BKV=q.shape[0] * k.shape[2],
            G=q.shape[2] // k.shape[2], hd=q.shape[3],
            Tq=q.shape[1], Tk=k.shape[1], causal=causal)
        if block_q is None:
            block_q = cfg["block_q"] if cfg else DEFAULT_BLOCK_Q
        if block_k is None:
            block_k = cfg["block_k"] if cfg else DEFAULT_BLOCK_K
    return _flash_attention(q, k, v, causal=causal, window=window,
                            logit_cap=logit_cap, q_offset=q_offset,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_cap", "q_offset",
                     "block_q", "block_k", "interpret"))
def _flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
    logit_cap: Optional[float],
    q_offset: int,
    block_q: int,
    block_k: int,
    interpret: Optional[bool],
) -> jax.Array:
    if interpret is None:
        interpret = _on_cpu()
    B, Tq, H, hd = q.shape
    _, Tk, KV, _ = k.shape
    G = H // KV

    block_q = min(block_q, Tq) if Tq >= 8 else Tq
    block_k = min(block_k, Tk) if Tk >= 8 else Tk

    pad_q = (-Tq) % block_q
    pad_k = (-Tk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    Tqp, Tkp = qp.shape[1], kp.shape[1]

    # (B, T, KV, G, hd) -> (B*KV, G, T, hd)
    q4 = qp.reshape(B, Tqp, KV, G, hd).transpose(0, 2, 3, 1, 4).reshape(
        B * KV, G, Tqp, hd)
    k3 = kp.transpose(0, 2, 1, 3).reshape(B * KV, Tkp, hd)
    v3 = vp.transpose(0, 2, 1, 3).reshape(B * KV, Tkp, hd)

    # Padded K positions are masked inside the kernel via the static
    # `kv_len`: pad keys get position >= Tk and a NEG_INF logit, which the
    # online softmax then ignores — correct for causal and non-causal alike
    # (causal alone also guards them when q_offset + Tq <= Tk).
    out = flash_attention_fwd(
        q4, k3, v3, causal=causal, window=window, logit_cap=logit_cap,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=interpret, kv_len=Tk if pad_k else None)
    out = out.reshape(B, KV, G, Tqp, hd).transpose(0, 3, 1, 2, 4).reshape(
        B, Tqp, H, hd)
    return out[:, :Tq]
