"""Pure-jnp oracle for the flash-attention kernel (naive full-matrix)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(
    q: jax.Array,                # (B, Tq, H, hd)
    k: jax.Array,                # (B, Tk, KV, hd)
    v: jax.Array,                # (B, Tk, KV, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    q_offset: int = 0,
) -> jax.Array:
    B, Tq, H, hd = q.shape
    _, Tk, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5
    qh = q.reshape(B, Tq, KV, G, hd).astype(jnp.float32) * scale
    kh = k.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qh, kh)
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)
    qpos = q_offset + jnp.arange(Tq)
    kpos = jnp.arange(Tk)
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Tq, H, hd).astype(q.dtype)
