"""Pallas TPU flash-attention forward kernel (blockwise online softmax).

TPU-native layout: the GQA group axis is folded into the query-tile rows so
every MXU matmul is (G*block_q, hd) x (hd, block_k) — hardware-aligned when
block_q/block_k are multiples of 128.  Grid = (B*KV, nq, nk); the nk axis is
"arbitrary" (sequential) and accumulates into VMEM scratch; fully-masked
causal / out-of-window K tiles are skipped with ``pl.when``.

Validated on CPU in interpret mode against ``ref.attention_ref``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

try:  # TPU compiler params are optional (ignored in interpret mode)
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM

    def _compiler_params():
        try:
            return pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary"))
        except Exception:
            return None
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

    def _compiler_params():
        return None

NEG_INF = -2.0 ** 30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_k: int, nk: int, causal: bool,
            window: Optional[int], logit_cap: Optional[float],
            q_offset: int, scale: float, groups: int,
            kv_len: Optional[int]):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    rows = groups * block_q
    q0 = q_offset + qi * block_q
    k0 = ki * block_k

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # --- tile-level skip for fully-masked K tiles ---------------------------
    run = True
    if causal:
        # last q position in tile vs first k position in tile
        run = jnp.asarray(k0 <= q0 + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, k0 + block_k - 1 > q0 - window)
    if kv_len is not None and kv_len < nk * block_k:
        # tiles entirely inside the key padding contribute nothing
        run = jnp.logical_and(jnp.asarray(run), k0 < kv_len)

    @pl.when(run if not isinstance(run, bool) else True)
    def _compute():
        q = q_ref[0].reshape(rows, q_ref.shape[-1])          # (G*bq, hd)
        k = k_ref[0]                                          # (bk, hd)
        v = v_ref[0]
        s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
        if logit_cap is not None:
            s = logit_cap * jnp.tanh(s / logit_cap)

        qpos = q0 + lax.broadcasted_iota(jnp.int32, (rows, block_k), 0) % block_q
        kpos = k0 + lax.broadcasted_iota(jnp.int32, (rows, block_k), 1)
        mask = jnp.ones((rows, block_k), bool)
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        if kv_len is not None and kv_len < nk * block_k:
            mask = mask & (kpos < kv_len)     # zero-padded keys are invalid
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            l_ref[:, :1] * corr + p.sum(axis=1, keepdims=True), l_ref.shape)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        pv = lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        out = (acc_ref[...] / l).astype(o_ref.dtype)
        o_ref[0] = out.reshape(o_ref.shape[1:])


def flash_attention_fwd(
    q: jax.Array,                 # (BKV, G, Tq, hd)
    k: jax.Array,                 # (BKV, Tk, hd)
    v: jax.Array,                 # (BKV, Tk, hd)
    *,
    causal: bool,
    window: Optional[int],
    logit_cap: Optional[float],
    q_offset: int,
    block_q: int,
    block_k: int,
    interpret: bool,
    kv_len: Optional[int] = None,
) -> jax.Array:
    BKV, G, Tq, hd = q.shape
    Tk = k.shape[1]
    assert Tq % block_q == 0 and Tk % block_k == 0, (Tq, Tk, block_q, block_k)
    nq, nk = Tq // block_q, Tk // block_k
    rows = G * block_q
    scale = hd ** -0.5

    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, nk=nk, causal=causal,
        window=window, logit_cap=logit_cap, q_offset=q_offset, scale=scale,
        groups=G, kv_len=kv_len)

    if _VMEM is not None:
        scratch = [
            _VMEM((rows, 128), jnp.float32),
            _VMEM((rows, 128), jnp.float32),
            _VMEM((rows, hd), jnp.float32),
        ]
    else:  # pragma: no cover
        scratch = [
            pl.MemorySpace.ANY((rows, 128), jnp.float32),  # type: ignore
        ]

    cp = _compiler_params()
    kwargs = {"compiler_params": cp} if cp is not None else {}

    return pl.pallas_call(
        kernel,
        grid=(BKV, nq, nk),
        in_specs=[
            pl.BlockSpec((1, G, block_q, hd), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, block_q, hd), lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BKV, G, Tq, hd), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(q, k, v)
