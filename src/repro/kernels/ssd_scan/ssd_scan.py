"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid = (B, H, nc): batch and head axes are parallel; the chunk axis is
sequential ("arbitrary") with the running state (P, N) held in VMEM scratch.
Per chunk the kernel computes the intra-chunk quadratic term
(L ⊙ C Bᵀ) · (dt x) plus the inter-chunk contribution C · S_in, then advances
the state — i.e. the state-space-dual form where both heavy products are MXU
matmuls of shape (chunk, N)x(N, chunk) and (chunk, chunk)x(chunk, P).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM

    def _compiler_params():
        try:
            return pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary"))
        except Exception:
            return None
except Exception:  # pragma: no cover
    _VMEM = None

    def _compiler_params():
        return None


def _kernel(xdt_ref, dA_ref, b_ref, c_ref, y_ref, state_out_ref, s_ref, *,
            chunk: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    xdt = xdt_ref[0, 0].astype(jnp.float32)       # (chunk, P)  x*dt
    dA = dA_ref[0, 0].astype(jnp.float32)         # (chunk, 1)  dt*A (log decay)
    Bc = b_ref[0].astype(jnp.float32)             # (chunk, N)
    Cc = c_ref[0].astype(jnp.float32)             # (chunk, N)

    cum = jnp.cumsum(dA, axis=0)                  # (chunk, 1)
    seg = cum - cum.T                             # (chunk, chunk) log decay t<-s
    rows = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(rows >= cols, jnp.exp(seg), 0.0)

    scores = lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    W = scores * L                                # (chunk, chunk)
    y = lax.dot_general(W, xdt, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32)   # (chunk, P)

    # inter-chunk: y += exp(cum) * (C @ state^T);  state: (P, N)
    state = s_ref[...]
    y_in = lax.dot_general(Cc, state, (((1,), (1,)), ((), ())),
                           preferred_element_type=jnp.float32)  # (chunk, P)
    y = y + jnp.exp(cum) * y_in
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S = S * exp(cum[-1]) + xdt^T @ (B * decay_to_end)
    decay_to_end = jnp.exp(cum[-1:] - cum)        # (chunk, 1)
    S_local = lax.dot_general(xdt, Bc * decay_to_end, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    s_ref[...] = state * jnp.exp(cum[-1]) + S_local

    @pl.when(ci == nc - 1)
    def _final():
        state_out_ref[0, 0] = s_ref[...]


def ssd_scan_fwd(
    xdt: jax.Array,   # (B, H, T, P)  pre-multiplied x * dt
    dA: jax.Array,    # (B, H, T, 1)  dt * A  (negative log-decay)
    Bm: jax.Array,    # (B, T, N)
    Cm: jax.Array,    # (B, T, N)
    *,
    chunk: int,
    interpret: bool,
):
    B, H, T, P = xdt.shape
    N = Bm.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk

    kernel = functools.partial(_kernel, chunk=chunk, nc=nc)
    if _VMEM is None:  # pragma: no cover
        raise RuntimeError("pallas tpu backend unavailable")
    scratch = [_VMEM((P, N), jnp.float32)]
    cp = _compiler_params()
    kwargs = {"compiler_params": cp} if cp is not None else {}

    y, final_state = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(xdt, dA, Bm, Cm)
    return y, final_state
