"""Jit'd public wrapper for the SSD-scan Pallas kernel (model layout)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_fwd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,     # (B, T, H, P)
    dt: jax.Array,    # (B, T, H)  (already softplus'd)
    A: jax.Array,     # (H,) negative reals
    Bm: jax.Array,    # (B, T, N)
    Cm: jax.Array,    # (B, T, N)
    *,
    chunk: int = 128,
    interpret=None,
):
    """Returns (y (B,T,H,P) f32, final_state (B,H,P,N) f32)."""
    if interpret is None:
        interpret = _on_cpu()
    B, T, H, P = x.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)

    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
           ).transpose(0, 2, 1, 3)                       # (B,H,T,P)
    dA = (dt.astype(jnp.float32) * A).transpose(0, 2, 1)[..., None]  # (B,H,T,1)

    y, final_state = ssd_scan_fwd(xdt, dA, Bm, Cm, chunk=chunk,
                                  interpret=interpret)
    return y.transpose(0, 2, 1, 3), final_state
