"""Jit'd public wrapper for the SSD-scan Pallas kernel (model layout).

``chunk=None`` consults the autotune cache (``repro.perf.autotune``) for
the best-known chunk of this (shape-class, dtype, backend) and degrades
it to the largest divisor of T when the tuned value does not divide the
actual sequence length; an empty cache falls back to the historical 128.
Explicit kwargs win (and must divide T, as before).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_fwd
from repro.perf import autotune


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


DEFAULT_CHUNK = autotune.DEFAULTS["ssd_scan"]["chunk"]


def _largest_dividing_chunk(T: int, chunk: int) -> int:
    chunk = min(chunk, T)
    while T % chunk:
        chunk -= 1
    return chunk


def ssd_scan(
    x: jax.Array,     # (B, T, H, P)
    dt: jax.Array,    # (B, T, H)  (already softplus'd)
    A: jax.Array,     # (H,) negative reals
    Bm: jax.Array,    # (B, T, N)
    Cm: jax.Array,    # (B, T, N)
    *,
    chunk: Optional[int] = None,
    interpret=None,
):
    """Returns (y (B,T,H,P) f32, final_state (B,H,P,N) f32)."""
    if chunk is None:
        cfg = autotune.lookup("ssd_scan", x.dtype, H=x.shape[2],
                              P=x.shape[3], N=Bm.shape[2], T=x.shape[1])
        chunk = _largest_dividing_chunk(
            x.shape[1], cfg["chunk"] if cfg else DEFAULT_CHUNK)
    return _ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    *,
    chunk: int,
    interpret=None,
):
    if interpret is None:
        interpret = _on_cpu()
    B, T, H, P = x.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)

    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
           ).transpose(0, 2, 1, 3)                       # (B,H,T,P)
    dA = (dt.astype(jnp.float32) * A).transpose(0, 2, 1)[..., None]  # (B,H,T,1)

    y, final_state = ssd_scan_fwd(xdt, dA, Bm, Cm, chunk=chunk,
                                  interpret=interpret)
    return y.transpose(0, 2, 1, 3), final_state
