"""Pure-jnp oracle for the Mamba2 SSD scan: naive sequential recurrence.

Independent of the chunked implementation — recurses token by token:
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t^T
    y_t = C_t . h_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ssd_ref(x, dt, A, Bm, Cm, init_state=None):
    """x: (B,T,H,P); dt: (B,T,H); A: (H,); Bm/Cm: (B,T,N).

    Returns (y (B,T,H,P) f32, final_state (B,H,P,N) f32)."""
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    s0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, Bt, Ct = inp                     # (B,H,P) (B,H) (B,N) (B,N)
        dA = jnp.exp(dtt * A)                     # (B,H)
        h = h * dA[:, :, None, None] + jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, Bt)
        y = jnp.einsum("bhpn,bn->bhp", h, Ct)
        return h, y

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    final, ys = lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), final
