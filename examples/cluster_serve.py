"""Cluster-scale serving of the paper's full 30-job Table-4 trace.

Runs the whole workload end-to-end on a simulated fleet under two policies —
the paper's per-job DNNScaler (profile, then commit to Batching OR
Multi-Tenancy) and the joint-knob HybridScaler — and reports per-job SLO
attainment plus aggregate cluster throughput.  With --full it also runs the
pure-B / pure-MT ablations and the Clipper baseline.

    PYTHONPATH=src python examples/cluster_serve.py
    PYTHONPATH=src python examples/cluster_serve.py --devices 12 \
        --seconds 240 --full --json experiments/cluster.json
"""

import argparse
import json
import os

from repro.serving.cluster import run_paper_cluster


def print_report(rep, *, verbose=True):
    agg = rep["aggregate"]
    if verbose:
        print(f"{'job':>3} {'dnn/dataset':<26} {'dev':>12} {'appr':>4} "
              f"{'bs':>3} {'mtl':>3} {'thr/s':>8} {'p95*':>8} {'SLO':>7} "
              f"{'attain':>6} ok")
        for r in rep["per_job"]:
            ok = ("-" if not r["feasible"]
                  else "Y" if r["tail_p95_ms"] <= r["slo_ms"] else "N")
            print(f"{r['job_id']:>3} {r['dnn']:<26} {r['device']:>12} "
                  f"{r['approach']:>4} {r['bs']:>3} {r['mtl']:>3} "
                  f"{r['throughput']:>8.1f} {r['tail_p95_ms']:>7.1f}m "
                  f"{r['slo_ms']:>6.1f}m {r['slo_attainment']:>6.3f} {ok}")
        print("    (* steady-state p95 over the last half of the run; "
              "'-' = SLO infeasible even at bs=1 on its slice)")
    print(f"  => {agg['mode']:>7}: aggregate {agg['aggregate_throughput']:.1f}"
          f" items/s over {agg['devices']} devices, "
          f"{agg['jobs_meeting_slo']}/{agg['feasible_jobs']} feasible jobs "
          f"meet SLO, {agg['total_stall_s']:.1f}s instance stalls")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=12)
    ap.add_argument("--seconds", type=float, default=300.0)
    ap.add_argument("--seed", type=int, default=2)
    ap.add_argument("--full", action="store_true",
                    help="also run pure-B / pure-MT / clipper ablations")
    ap.add_argument("--json", default=None,
                    help="dump all reports to this JSON file")
    args = ap.parse_args()

    modes = ["auto", "hybrid"] + (["B", "MT", "clipper"] if args.full else [])
    reports = {}
    for mode in modes:
        rep = run_paper_cluster(mode, n_devices=args.devices,
                                sim_time_limit=args.seconds, seed=args.seed)
        reports[mode] = rep
        print_report(rep, verbose=(mode in ("auto", "hybrid")))
        print()

    thr = {m: reports[m]["aggregate"]["aggregate_throughput"] for m in modes}
    best_pure = max((thr.get("B", 0.0), thr.get("MT", 0.0), thr["auto"]))
    print(f"aggregate throughput: paper DNNScaler {thr['auto']:.1f}/s, "
          f"HybridScaler {thr['hybrid']:.1f}/s "
          f"(x{thr['hybrid'] / max(thr['auto'], 1e-9):.2f})")
    if args.full:
        print(f"pure-B {thr['B']:.1f}/s  pure-MT {thr['MT']:.1f}/s  "
              f"clipper {thr['clipper']:.1f}/s")
    ok_thr = thr["hybrid"] >= 0.99 * best_pure
    ok_slo = (reports["hybrid"]["aggregate"]["jobs_meeting_slo"]
              == reports["hybrid"]["aggregate"]["feasible_jobs"])
    print(f"hybrid >= best pure strategy: {'PASS' if ok_thr else 'FAIL'}; "
          f"SLO compliance (all feasible jobs): "
          f"{'PASS' if ok_slo else 'FAIL'}")

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(reports, f, indent=1)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
