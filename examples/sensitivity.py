"""SLO-sensitivity demo (paper §4.5, Figs 9-10): change the SLO mid-run and
watch DNNScaler re-adapt its knob — batch size for a Batching job
(Inception-V4), instance count for a Multi-Tenancy job (Inception-V1).

    PYTHONPATH=src python examples/sensitivity.py
"""

from repro.core.controller import DNNScalerController
from repro.core.matrix_completion import LatencyEstimator
from repro.serving import device_model as dm
from repro.serving.engine import ServingEngine
from repro.serving.executor import SimExecutor
from repro.serving.workload import PAPER_JOBS


def run_case(job, direction):
    prof = job.profile()
    if direction == "tighten":
        slo_fn = lambda t: job.slo_s if t < 60 else job.slo_s * 0.5
    else:
        slo_fn = lambda t: job.slo_s * 0.5 if t < 60 else job.slo_s

    est = LatencyEstimator(max_mtl=10)
    mtls = list(range(1, 11))
    for j in PAPER_JOBS[:8]:
        curve = dm.mt_latency_curve(dm.TESLA_P40, j.profile(), 1, mtls)
        est.add_library_row(dict(zip(mtls, curve)))
    ctrl = DNNScalerController(SimExecutor(prof, seed=0), slo_fn(0.0),
                               estimator=est)
    eng = ServingEngine(SimExecutor(prof, seed=1), slo_fn(0.0),
                        slo_schedule=slo_fn)
    eng.run(ctrl, max_steps=4000, sim_time_limit=130.0)

    knob_i = 1 if ctrl.approach == "B" else 2
    knob_name = "BS" if ctrl.approach == "B" else "MTL"
    print(f"\n{prof.name} ({ctrl.approach}) — SLO {direction}s at t=60s:")
    last_t = -10.0
    for t, bs, mtl, p95, thr, slo in eng.acc.trace:
        if t - last_t >= 10.0:
            knob = bs if knob_i == 1 else mtl
            print(f"  t={t:6.1f}s  SLO={slo * 1e3:6.0f}ms  {knob_name}={knob:>3} "
                  f"p95={p95 * 1e3:6.1f}ms  thr={thr:7.1f}/s")
            last_t = t


def main():
    run_case(PAPER_JOBS[2], "tighten")   # Inception-V4: Batching (Fig 9a)
    run_case(PAPER_JOBS[2], "relax")     # (Fig 9b)
    run_case(PAPER_JOBS[0], "tighten")   # Inception-V1: Multi-Tenancy (Fig 10a)
    run_case(PAPER_JOBS[0], "relax")     # (Fig 10b)


if __name__ == "__main__":
    main()
