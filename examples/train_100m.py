"""End-to-end training driver: a ~100M-parameter llama-family model trained
for a few hundred steps on the synthetic Markov-Zipf corpus, with AdamW,
checkpointing, and live loss logging.

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--quick]
"""

import argparse

from repro.configs.base import get_config
from repro.training.loop import train


def config_100m():
    """SmolLM-family scaled to ~100M params (12L, d=640, 32k vocab)."""
    return get_config("smollm-360m").replace(
        name="smollm-100m",
        num_layers=12,
        d_model=640,
        num_heads=10,
        num_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab_size=32768,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--quick", action="store_true",
                    help="tiny config + 40 steps (CI-speed)")
    ap.add_argument("--ckpt", default="experiments/train100m_ckpt.npz")
    args = ap.parse_args()

    if args.quick:
        cfg = get_config("smollm-360m", tiny=True)
        args.steps = min(args.steps, 40)
    else:
        cfg = config_100m()
    print(f"config {cfg.name}: ~{cfg.param_count() / 1e6:.0f}M params "
          f"(analytic); {args.steps} steps, batch {args.batch}, seq {args.seq}")
    out = train(cfg, steps=args.steps, batch_size=args.batch,
                seq_len=args.seq, lr=args.lr, log_every=10,
                ckpt_path=args.ckpt, ckpt_every=max(args.steps // 3, 1))
    print(f"\nfinal: {out['n_params']:,} params | loss "
          f"{out['losses'][0]:.3f} -> {out['final_loss']:.3f} | "
          f"{out['wall_s']:.0f}s wall | checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
