"""Record a churn run, then replay it under counterfactual policies.

The capacity-planning loop the replay planner enables: serve a recorded
production window once (here: the standard churn trace under dynamic
placement), persist its inputs + event stream into the profile store,
then — without re-specifying anything — ask what the SAME workload would
have achieved under different operating decisions:

  baseline       the recorded policy, verbatim.  Replay determinism is
                 asserted: the replayed report equals the recorded run's
                 report EXACTLY (same seeds, same floats), so every
                 counterfactual delta is attributable to the policy
                 change alone, not simulator noise;
  uniform-mtl    uniform multi-tenancy everywhere instead of the hybrid
                 per-job batching/MTL choice (the paper's MT column,
                 forced fleet-wide);
  mig            the same tenancies on a MIG-partitioned fleet: discrete
                 hardware slices, churn handled by partition resizes
                 instead of kill+relaunch migrations;
  fewer-devices  the recorded workload on 80% of the fleet — the
                 "can we hand two machines back?" question.

    PYTHONPATH=src python examples/replay_whatif.py
    PYTHONPATH=src python examples/replay_whatif.py --devices 5 \
        --seconds 100 --store /tmp/replay_store
"""

import argparse
import tempfile

from repro.perf.profile_store import ProfileStore
from repro.serving import replay as rp
from repro.serving.cluster import run_churn_cluster
from repro.serving.workload import churn_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--seconds", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--store", default=None,
                    help="profile store dir (default: a temp dir)")
    args = ap.parse_args()

    root = args.store or tempfile.mkdtemp(prefix="replay_store_")
    store = ProfileStore(root)

    trace = churn_trace(horizon_s=args.seconds, n_initial=3, n_churn=6,
                        seed=args.seed)
    print(f"recording: {len(trace)} tenancies, {args.devices} devices, "
          f"{args.seconds:.0f}s horizon -> store {root}")
    rep = run_churn_cluster("dynamic", trace=trace,
                            n_devices=args.devices,
                            horizon_s=args.seconds, seed=args.seed,
                            record="whatif", record_store=store)
    agg = rep["aggregate"]
    print(f"recorded: goodput {agg['goodput']:.1f}/s, "
          f"throughput {agg['aggregate_throughput']:.1f}/s, "
          f"{agg['migrations']} migrations\n")

    recorded = rp.load_trace(store, "whatif")

    # determinism contract: baseline replay == the recorded run, exactly
    assert rp.replay_run(recorded) == rep, \
        "baseline replay diverged from the recorded run"
    print("baseline replay reproduces the recorded report exactly: PASS\n")

    rows = rp.replay_diff(recorded, profile_store=store)
    print(rp.diff_table(rows))
    by = {r["policy"]: r for r in rows}
    print(f"\nwhat-if: shrinking the fleet to "
          f"{by['fewer-devices']['devices']} devices keeps "
          f"{100 * by['fewer-devices']['goodput_vs_recorded']:.0f}% of "
          f"goodput; forcing uniform MTL keeps "
          f"{100 * by['uniform-mtl']['goodput_vs_recorded']:.0f}%; "
          f"a MIG'd fleet keeps "
          f"{100 * by['mig']['goodput_vs_recorded']:.0f}% with "
          f"{by['mig']['migrations']} migrations")


if __name__ == "__main__":
    main()
