"""Cross-run warm start: the second process pays less than the first.

One serving 'process' = a fresh executor + hybrid DNNScalerController,
with EVERYTHING cross-run flowing through the persistent profile store
(`perf.profile_store`): the run reloads persisted surface rows before
serving and persists its own probed row afterwards.

The executor is a RealExecutor whose AOT bucket compiles are REAL XLA
compiles — the stall the store amortizes — while the step latency the
controller observes comes from the calibrated analytic device model with
seeded noise.  Real wall-clock latency on a shared CI host swings 2-3x
between runs, which would turn a cold-vs-warm trajectory comparison into
a coin flip; the deterministic surface keeps the probe trajectories
reproducible while every bucket the search touches still pays its real
compile.  (Sim-vs-real latency fidelity is tested separately in
tests/test_conformance.py.)

The cold run climbs the (bs, mtl) knob space from scratch — every probe
is a new operating point and many land in new batch buckets, each paying
an AOT compile stall.  The warm run (same store dir, fresh process) finds
the previous run's persisted row, seeds + starts its scaler from the
matrix-completion prediction (including the infeasible-frontier pins the
cold run paid probes to discover), and reaches steady state in strictly
fewer distinct probes with strictly lower compile-stall seconds.

    PYTHONPATH=src python examples/warm_start.py
    PYTHONPATH=src python examples/warm_start.py --store /tmp/ps --phase cold
    PYTHONPATH=src python examples/warm_start.py --store /tmp/ps --phase warm

The one-shot default runs cold then warm against a fresh store dir; the
--phase form demonstrates the same thing across two real OS processes.
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import DNNScalerController
from repro.core.matrix_completion import SurfaceLibrary
from repro.perf import autotune
from repro.perf.profile_store import ProfileStore
from repro.serving import device_model as dm
from repro.serving.engine import ServingEngine
from repro.serving.executor import RealExecutor
from repro.serving.workload import PAPER_JOBS

SIGNATURE = "warmstart-inception_v4/imagenet"
DEVICE_CLASS = "host-cpu"
# inception_v4/imagenet (Table-4 job 3): a Batching job with a LONG climb
# (paper steady BS 28) — the cold search pays many probes and bucket
# compiles walking up, which is exactly the cost a warm start amortizes
JOB = PAPER_JOBS[2]
WIDTH = 128


class WarmLabExecutor(RealExecutor):
    """RealExecutor with a deterministic latency surface.

    XLA compiles per batch bucket are real (`cache_stats`,
    ``result["compile_time"]`` — the engine charges them as stalls); the
    reported step latency is the calibrated analytic model + seeded
    noise, so the scaler's probe trajectory is reproducible."""

    def __init__(self, profile: dm.JobProfile,
                 device: dm.Device = dm.TESLA_P40, seed: int = 0):
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        params = [jax.random.normal(k, (WIDTH, WIDTH)) * 0.05 for k in ks]

        def fn(params, batch):
            x = batch["x"]
            for w in params:
                x = jnp.tanh(x @ w)
            return x.sum()

        def make_batch(n):
            return {"x": jnp.ones((n, WIDTH), jnp.float32)}

        super().__init__(fn, params, make_batch)
        self.profile = profile
        self.device = device
        self.sampler = dm.LatencySampler(seed=seed)

    def mean_latency(self, bs: int, mtl: int = 1, iters: int = 3) -> float:
        return dm.mt_latency(self.device, self.profile, bs, mtl)

    def run_step(self, bs: int, mtl: int) -> dict:
        res = super().run_step(bs, mtl)     # real execution + compile bill
        mean = dm.mt_latency(self.device, self.profile, bs, mtl)
        lat = float(self.sampler.sample(mean, n=1)[0])
        items = bs * mtl
        res.update(step_time=lat,
                   request_latencies=self.sampler.sample(
                       lat, n=min(items, 64)),
                   throughput=items / lat)
        return res


def serve_once(store_dir: str, *, steps: int = 160, seed: int = 0) -> dict:
    """One serving process.  All cross-run state lives in the store on
    disk, so calling this twice IS the two-process experiment."""
    store = ProfileStore(store_dir)
    lib = SurfaceLibrary()
    gen = autotune.generation()
    res = store.load_surfaces(lib, device_class=DEVICE_CLASS,
                              autotune_generation=gen)
    ex = WarmLabExecutor(JOB.profile(), seed=seed)
    ctrl = DNNScalerController(ex, JOB.slo_s, mode="hybrid",
                               surface_library=lib, surface_key="tenant")
    engine = ServingEngine(ex, JOB.slo_s)
    acc = engine.run(ctrl, max_steps=steps)
    # tile_dependent=False: the latency surface is the analytic model,
    # so a kernel re-tune cannot invalidate it
    store.persist_surface(lib, "tenant", signature=SIGNATURE,
                          device_class=DEVICE_CLASS,
                          autotune_generation=gen, tile_dependent=False)
    store.save()
    last = [(bs, mtl) for _, bs, mtl, *_ in acc.trace[-40:]]
    return {
        "loaded_rows": len(res["loaded"]),
        "probes": ctrl.probe_count,
        "compiles": ex.cache_stats.misses,
        "compile_stall_s": acc.compile_stall_s,
        "steady": max(set(last), key=last.count),
        "throughput": acc.throughput,
        "slo_ms": JOB.slo_ms,
    }


def show(label: str, r: dict) -> None:
    print(f"{label:>5}: {r['loaded_rows']} persisted rows loaded, "
          f"{r['probes']} probes, {r['compiles']} bucket compiles "
          f"({r['compile_stall_s'] * 1e3:.0f}ms compile stalls), "
          f"steady (bs={r['steady'][0]}, mtl={r['steady'][1]}), "
          f"{r['throughput']:.0f} items/s (SLO {r['slo_ms']:.1f}ms)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", default=None,
                    help="profile store dir (default: a fresh temp dir)")
    ap.add_argument("--phase", default="both",
                    choices=["both", "cold", "warm"],
                    help="'cold'/'warm' run ONE phase (two real OS "
                         "processes against the same --store); 'both' "
                         "runs the whole experiment in one go")
    ap.add_argument("--steps", type=int, default=160)
    args = ap.parse_args()

    store_dir = args.store or tempfile.mkdtemp(prefix="profile_store_")
    print(f"profile store: {store_dir}")
    cold = warm = None
    if args.phase in ("both", "cold"):
        cold = serve_once(store_dir, steps=args.steps)
        show("cold", cold)
    if args.phase in ("both", "warm"):
        warm = serve_once(store_dir, steps=args.steps)
        show("warm", warm)
    if args.phase == "warm" and not warm["loaded_rows"]:
        print("store was empty — run --phase cold against the same "
              "--store first")
        return
    if cold is not None and warm is not None:
        ok = (warm["probes"] < cold["probes"]
              and warm["compile_stall_s"] < cold["compile_stall_s"])
        print(f"warm run reaches steady state in fewer probes "
              f"({warm['probes']} < {cold['probes']}) with lower compile "
              f"stalls ({warm['compile_stall_s'] * 1e3:.0f}ms < "
              f"{cold['compile_stall_s'] * 1e3:.0f}ms): "
              f"{'PASS' if ok else 'FAIL'}")
        if not ok:
            raise SystemExit(1)       # scripts/CI gate on the exit status


if __name__ == "__main__":
    main()
