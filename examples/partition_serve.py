"""Spatial partition sharing: heterogeneous MPS/MIG-style slices vs the
uniform multi-tenancy the paper's knob implies.

A mixed small/large-DNN churn trace (two heavy dense nets that need ~3/4
of a device each, plus light mobile/text nets churning in and out) is
served under three policies, all priced by the SAME calibrated spatial
model (uniform 1/k MPS shares reproduce the paper's MTL curves
bit-identically, so the comparison isolates the policy):

  uniform — every co-resident gets the equal 1/k slice and every share
            change is a full kill+relaunch migration round (the
            time-slicing baseline);
  het     — heterogeneous MPS shares: the HybridScaler's third
            coordinate-descent axis requests slices off a discrete
            ladder, the engine mediates grants against device headroom,
            and churn is absorbed by cheap partition RESIZES (contexts
            stay alive) instead of migrations;
  het-mig — the same on the discrete MIG profile grid (hardware
            isolation, shares snapped to legal profiles).

Asserted here (the PR's acceptance bar):
  * heterogeneous-share placement strictly beats uniform MTL aggregate
    goodput on the mixed trace;
  * the het run's churn resize stalls stay strictly below what the very
    same events would have cost as migrations;
  * request conservation holds for every policy.

    PYTHONPATH=src python examples/partition_serve.py
    PYTHONPATH=src python examples/partition_serve.py --devices 2 \
        --seconds 120 --seed 1 --json experiments/partition.json
"""

import argparse
import json
import os

from repro.serving.cluster import PARTITION_POLICIES, run_partition_cluster
from repro.serving.workload import mixed_partition_trace


def print_report(rep, *, verbose=True):
    agg = rep["aggregate"]
    if verbose:
        print(f"{'job':>5} {'dnn/dataset':<26} {'dev':>12} {'share':>6} "
              f"{'bs':>3} {'mtl':>3} {'rsz':>3} {'mig':>3} {'thr/s':>8} "
              f"{'attain':>6}")
        for r in rep["per_job"]:
            share = f"{r['share']:.3f}" if r["share"] is not None else "—"
            print(f"{r['job_id']:>5} {r['dnn']:<26} {r['device']:>12} "
                  f"{share:>6} {r['bs']:>3} {r['mtl']:>3} "
                  f"{r['resizes']:>3} {r['migrations']:>3} "
                  f"{r['throughput']:>8.1f} {r['slo_attainment']:>6.3f}")
    print(f"  => {agg['policy']:>7}: goodput {agg['goodput']:.1f}/s, "
          f"throughput {agg['aggregate_throughput']:.1f}/s, "
          f"{agg['resizes']} resizes ({agg['resize_stall_s']:.2f}s; "
          f"as migrations: {agg['resize_equiv_migration_stall_s']:.1f}s), "
          f"{agg['migrations']} migrations "
          f"({agg['migration_stall_s']:.1f}s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=2)
    ap.add_argument("--seconds", type=float, default=120.0)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--controller", default="hybrid",
                    choices=["hybrid", "dnnscaler"])
    ap.add_argument("--json", default=None,
                    help="dump all reports to this JSON file")
    args = ap.parse_args()

    mode = "hybrid" if args.controller == "hybrid" else "auto"
    # one shared trace so every policy serves the identical workload
    trace = mixed_partition_trace(horizon_s=args.seconds, n_light=5,
                                  seed=args.seed)
    heavy = sum(1 for e in trace if e.job.job_id < 2100)
    print(f"mixed trace: {len(trace)} tenancies ({heavy} heavy, "
          f"{len(trace) - heavy} light churners) over "
          f"{args.seconds:.0f}s on {args.devices} devices")
    print()

    reports = {}
    for policy in PARTITION_POLICIES:
        rep = run_partition_cluster(policy, trace=list(trace), mode=mode,
                                    n_devices=args.devices,
                                    horizon_s=args.seconds, seed=args.seed)
        reports[policy] = rep
        for r in rep["per_job"]:
            assert r["submitted"] == (r["completed"] + r["rejected"]
                                      + r["backlog"]), \
                f"conservation violated for job {r['job_id']} ({policy})"
        assert rep["aggregate"]["conserved"]
        print_report(rep, verbose=(policy != "uniform"))
        print()

    g = {p: reports[p]["aggregate"]["goodput"] for p in PARTITION_POLICIES}
    het = reports["het"]["aggregate"]
    print(f"aggregate goodput: uniform-MTL {g['uniform']:.1f}/s, "
          f"heterogeneous {g['het']:.1f}/s "
          f"(x{g['het'] / max(g['uniform'], 1e-9):.2f}), "
          f"MIG grid {g['het-mig']:.1f}/s")
    ok_goodput = g["het"] > g["uniform"]
    ok_resize = (het["resize_stall_s"]
                 < het["resize_equiv_migration_stall_s"])
    print(f"heterogeneous shares beat uniform MTL: "
          f"{'PASS' if ok_goodput else 'FAIL'}; "
          f"resize stalls ({het['resize_stall_s']:.2f}s) strictly below "
          f"the same events as migrations "
          f"({het['resize_equiv_migration_stall_s']:.1f}s): "
          f"{'PASS' if ok_resize else 'FAIL'}")
    assert ok_goodput and ok_resize

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(reports, f, indent=1)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
