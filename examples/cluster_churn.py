"""Online job churn on the simulated cluster: jobs arrive and depart
mid-run, and the engine re-places them with explicit migration costs.

Compares three placement policies on one churn trace (Table-4 pool plus
LLM decode jobs, Poisson arrivals at 60% of each job's full-device
SLO-feasible capacity):

  union    — static placement over the union of every tenancy that ever
             appears: the over-provisioned baseline, where every share is
             thinned by tenants that are not even there yet (or already
             left);
  dynamic  — online admission/draining: incremental SLO-aware packing that
             anticipates each job's predicted hybrid steady state,
             migration-aware relocation when direct placement leaves a job
             underserved, and drain-time rebalancing — every share change
             pays an instance kill+relaunch stall (plus checkpoint
             transfer on TPU submesh moves);
  surface  — dynamic plus the cross-job shared latency surface: probed
             (bs, mtl) points pool into a jobs x knobs matrix completed by
             soft-impute, and a newly admitted job with architecturally
             similar history seeds (and starts) its HybridScaler from the
             completed row instead of climbing from the analytic floor.

Request conservation — submitted == completed + rejected + backlog, per
job — is asserted for every policy.

    PYTHONPATH=src python examples/cluster_churn.py
    PYTHONPATH=src python examples/cluster_churn.py --devices 5 \
        --seconds 150 --seed 2 --json experiments/churn.json
"""

import argparse
import json
import os

from repro.serving.cluster import CHURN_POLICIES, run_churn_cluster
from repro.serving.workload import churn_trace


def print_report(rep, *, verbose=True):
    agg = rep["aggregate"]
    if verbose:
        print(f"{'job':>4} {'dnn/dataset':<26} {'dev':>12} {'life':>13} "
              f"{'bs':>3} {'mtl':>3} {'thr/s':>8} {'mig':>3} {'sub':>7} "
              f"{'comp':>7} {'rej':>6} {'attain':>6}")
        for r in rep["per_job"]:
            end = r["drained_at"] if r["drained_at"] is not None else "end"
            life = f"{r['admit_s']:.0f}-" + (
                f"{end:.0f}" if isinstance(end, float) else end)
            print(f"{r['job_id']:>4} {r['dnn']:<26} {r['device']:>12} "
                  f"{life:>13} {r['bs']:>3} {r['mtl']:>3} "
                  f"{r['throughput']:>8.1f} {r['migrations']:>3} "
                  f"{r['submitted']:>7} {r['completed']:>7} "
                  f"{r['rejected']:>6} {r['slo_attainment']:>6.3f}")
    print(f"  => {agg['policy']:>7}: goodput {agg['goodput']:.1f}/s, "
          f"throughput {agg['aggregate_throughput']:.1f}/s, "
          f"{agg['admissions']} admissions / {agg['drains']} drains / "
          f"{agg['migrations']} migrations "
          f"({agg['migration_stall_s']:.1f}s migration stalls)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=5)
    ap.add_argument("--seconds", type=float, default=150.0)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--controller", default="hybrid",
                    choices=["hybrid", "dnnscaler"])
    ap.add_argument("--json", default=None,
                    help="dump all reports to this JSON file")
    args = ap.parse_args()

    mode = "hybrid" if args.controller == "hybrid" else "auto"
    # one shared trace so every policy serves the identical workload
    trace = churn_trace(horizon_s=args.seconds, seed=args.seed)
    print(f"churn trace: {len(trace)} tenancies over {args.seconds:.0f}s "
          f"on {args.devices} devices "
          f"({sum(1 for e in trace if e.admit_s > 0)} arrive mid-run, "
          f"{sum(1 for e in trace if e.depart_s is not None)} depart)")
    print()

    reports = {}
    for policy in CHURN_POLICIES:
        rep = run_churn_cluster(policy, trace=list(trace), mode=mode,
                                n_devices=args.devices,
                                horizon_s=args.seconds, seed=args.seed)
        reports[policy] = rep
        # request conservation must hold across every reconfiguration
        for r in rep["per_job"]:
            assert r["submitted"] == (r["completed"] + r["rejected"]
                                      + r["backlog"]), \
                f"conservation violated for job {r['job_id']} ({policy})"
        assert rep["aggregate"]["conserved"]
        print_report(rep, verbose=(policy != "union"))
        print()

    g = {p: reports[p]["aggregate"]["goodput"] for p in CHURN_POLICIES}
    print(f"aggregate goodput: static-union {g['union']:.1f}/s, "
          f"dynamic {g['dynamic']:.1f}/s "
          f"(x{g['dynamic'] / max(g['union'], 1e-9):.2f}), "
          f"dynamic+surface {g['surface']:.1f}/s "
          f"(x{g['surface'] / max(g['union'], 1e-9):.2f})")
    ok = g["surface"] > g["union"]
    print(f"dynamic re-placement + shared surface beats static-union "
          f"placement: {'PASS' if ok else 'FAIL'}; request conservation "
          f"held for all {sum(len(r['per_job']) for r in reports.values())} "
          f"job rows: PASS")

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(reports, f, indent=1)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
