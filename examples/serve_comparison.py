"""End-to-end serving driver: DNNScaler vs Clipper on a slice of the paper's
30-job workload (calibrated simulator) — a miniature of Fig. 5 / Table 6.

    PYTHONPATH=src python examples/serve_comparison.py [--jobs 1,3,5,19,26]
"""

import argparse

import numpy as np

from repro.core.clipper import ClipperController
from repro.core.controller import DNNScalerController
from repro.core.matrix_completion import LatencyEstimator
from repro.serving import device_model as dm
from repro.serving.engine import ServingEngine
from repro.serving.executor import SimExecutor
from repro.serving.workload import PAPER_JOBS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", default="1,3,5,12,19,26")
    ap.add_argument("--seconds", type=float, default=240.0)
    args = ap.parse_args()
    ids = [int(x) for x in args.jobs.split(",")]

    est = LatencyEstimator(max_mtl=10)
    mtls = list(range(1, 11))
    for j in PAPER_JOBS[:8]:
        curve = dm.mt_latency_curve(dm.TESLA_P40, j.profile(), 1, mtls)
        est.add_library_row(dict(zip(mtls, curve)))

    print(f"{'job':>22} {'paper':>5} {'ours':>4} {'knob':>8} "
          f"{'DNNScaler':>10} {'Clipper':>9} {'speedup':>8} {'p95/SLO':>8}")
    ratios = []
    for jid in ids:
        job = PAPER_JOBS[jid - 1]
        prof = job.profile()
        ctrl = DNNScalerController(SimExecutor(prof, seed=jid), job.slo_s,
                                   estimator=est)
        eng = ServingEngine(SimExecutor(prof, seed=jid + 1), job.slo_s)
        acc = eng.run(ctrl, max_steps=6000, sim_time_limit=args.seconds)
        eng2 = ServingEngine(SimExecutor(prof, seed=jid + 2), job.slo_s)
        acc2 = eng2.run(ClipperController(job.slo_s), max_steps=6000,
                        sim_time_limit=args.seconds)
        a = ctrl.action()
        knob = f"BS={a.bs}" if ctrl.approach == "B" else f"MTL={a.mtl}"
        ratio = acc.throughput / max(acc2.throughput, 1e-9)
        ratios.append(ratio)
        print(f"{prof.name:>22} {job.paper_method:>5} {ctrl.approach:>4} "
              f"{knob:>8} {acc.throughput:>8.1f}/s {acc2.throughput:>7.1f}/s "
              f"{ratio:>7.2f}x {acc.p95 / job.slo_s:>7.2f}")
    print(f"\ngeomean speedup: {np.exp(np.mean(np.log(ratios))):.2f}x "
          f"(paper: 218% avg, up to 14x on MT jobs)")


if __name__ == "__main__":
    main()
