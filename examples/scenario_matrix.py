"""The scenario matrix: {steady, diurnal, flash-crowd} traffic x
{fixed, spot} capacity x {power-packed, spread} placement, all served by
the MPS partition planner with the HybridScaler's share axis active.

Each cell runs the same six-light-tenant trace shape under one traffic
kind; spot cells additionally revoke one preemptible device mid-run
(residents get a grace window to evacuate).  The comparison the matrix
exists for: `pack` consolidates tenants onto few devices and power-gates
the rest, so it pays the idle floor on ~half the fleet — measurably
fewer joules per good request than `spread` at the SAME goodput and
>= 0.95 SLO attainment in every cell (the BENCH_scenarios gate).

Asserted here (the PR's acceptance bar):
  * every cell conserves requests (submitted == completed + rejected +
    backlog), including through spot revocations;
  * pack's joules-per-good-request beats spread's for every
    (traffic, capacity) pair at equal goodput;
  * spot cells actually fire their revocation.

    PYTHONPATH=src python examples/scenario_matrix.py
    PYTHONPATH=src python examples/scenario_matrix.py --seconds 240 \
        --seed 3 --json experiments/scenarios.json
"""

import argparse
import json
import os

from repro.serving.cluster import SCENARIO_TRAFFICS, run_scenario_cluster


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--seconds", type=float, default=150.0)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--controller", default="hybrid",
                    choices=["hybrid", "dnnscaler"])
    ap.add_argument("--vectorized", action="store_true")
    ap.add_argument("--json", default=None,
                    help="dump all cell reports to this JSON file")
    args = ap.parse_args()
    mode = "hybrid" if args.controller == "hybrid" else "auto"

    reports = {}
    print(f"{'cell':<24} {'goodput':>9} {'attain':>7} {'J/good':>8} "
          f"{'devs':>4} {'evac':>4} {'kill':>4}")
    for traffic in SCENARIO_TRAFFICS:
        for spot in (False, True):
            for policy in ("pack", "spread"):
                cell = f"{traffic}/{'spot' if spot else 'fixed'}/{policy}"
                rep = run_scenario_cluster(
                    traffic, spot=spot, power_policy=policy, mode=mode,
                    n_devices=args.devices, horizon_s=args.seconds,
                    seed=args.seed, vectorized=args.vectorized)
                a = rep["aggregate"]
                for r in rep["per_job"]:
                    assert r["submitted"] == (r["completed"] + r["rejected"]
                                              + r["backlog"]), \
                        f"conservation violated for job {r['job_id']} " \
                        f"({cell})"
                assert a["conserved"]
                if spot:
                    assert a["preemptions"] >= 1
                reports[cell] = rep
                jpg = a["joules_per_good_request"]
                print(f"{cell:<24} {a['goodput']:>7.1f}/s "
                      f"{a['min_attainment']:>7.3f} "
                      f"{f'{jpg:.4f}J' if jpg is not None else '—':>8} "
                      f"{a['devices_powered']:>4} "
                      f"{a['preempt_evacuated']:>4} "
                      f"{a['preempt_killed']:>4}")

    print()
    ok = True
    for traffic in SCENARIO_TRAFFICS:
        for cap in ("fixed", "spot"):
            jp = reports[f"{traffic}/{cap}/pack"]["aggregate"]
            js = reports[f"{traffic}/{cap}/spread"]["aggregate"]
            saved = 1.0 - (jp["joules_per_good_request"]
                           / js["joules_per_good_request"])
            cell_ok = saved > 0.0
            ok = ok and cell_ok
            print(f"{traffic}/{cap}: pack saves {saved:.1%} joules per "
                  f"good request vs spread "
                  f"({'PASS' if cell_ok else 'FAIL'})")
    assert ok, "power-packed placement failed to beat spread somewhere"

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(reports, f, indent=1)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
