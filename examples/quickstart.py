"""Quickstart: serve a (tiny, real) model under DNNScaler on this host.

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced SmolLM, measures real wall-clock latency, lets the Profiler
choose Batching vs Multi-Tenancy, and runs the Scaler loop against a 4x-base
latency SLO.
"""

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.controller import DNNScalerController
from repro.models import api
from repro.serving.engine import ServingEngine
from repro.serving.executor import RealExecutor


def main():
    cfg = get_config("smollm-360m", tiny=True)
    rng = jax.random.PRNGKey(0)
    params = api.init_params(rng, cfg)
    print(f"model: {cfg.name} ({sum(x.size for x in jax.tree.leaves(params)):,} params)")

    @jax.jit
    def serve_fn(params, batch):
        logits, _ = api.prefill(params, batch, cfg, capacity=48)
        return logits

    def make_batch(n):
        return {"tokens": jax.random.randint(rng, (n, 32), 0, cfg.vocab_size,
                                             jnp.int32)}

    executor = RealExecutor(serve_fn, params, make_batch)
    base = executor.mean_latency(1, 1)
    slo = base * 8
    print(f"base latency {base * 1e3:.1f}ms -> SLO {slo * 1e3:.1f}ms")

    ctrl = DNNScalerController(executor, slo, m=8, n=4, max_bs=32, max_mtl=4)
    print(f"profiler: TI_B={ctrl.profile.ti_b:.0f}% "
          f"TI_MT={ctrl.profile.ti_mt:.0f}% -> {ctrl.approach}")

    engine = ServingEngine(executor, slo, instance_launch_s=0.05)
    acc = engine.run(ctrl, max_steps=40)
    s = acc.summary()
    a = ctrl.action()
    print(f"steady state: bs={a.bs} mtl={a.mtl}")
    print(f"served {s['items']} requests @ {s['throughput']:.1f}/s, "
          f"p95 {s['p95_s'] * 1e3:.1f}ms (SLO {slo * 1e3:.1f}ms), "
          f"attainment {s['slo_attainment']:.2f}")


if __name__ == "__main__":
    main()
