"""Disaggregated prefill/decode serving vs the single-device prefill modes.

One long-prefill ragged decode trace (2048-token-mean prompts) served
four ways on the same device class:

  cotenant — prefill as a co-resident spatial tenant on the decode
             device (PR 7's default): decode steps inflate by the
             cross-tenant interference terms and every prompt pays the
             profile's monolithic budget-priced prefill;
  chunked  — prefill split into fixed token-budget chunks piggybacked
             into decode steps (priced as bs + chunk_tokens /
             decode_token_equiv on the existing latency grid): per-token
             prefill pricing, bounded decode interference;
  static   — the fixed-shape bucketed baseline;
  disagg   — a PrefillPool of dedicated prefill devices absorbs every
             prompt, the finished KV streams over the KVTransferFabric
             (per-device-class interconnect: bandwidth + latency floor)
             into a free decode slot.  TTFT = queue + prefill +
             transfer; TPOT stays pure decode.

Request conservation — submitted == completed + rejected + backlog, with
in-flight KV transfers folded into backlog — is asserted for every mode.
The `--json` output feeds `launch/report.py --disagg`.

    PYTHONPATH=src python examples/disagg_serve.py
    PYTHONPATH=src python examples/disagg_serve.py --rate 20 --pool 3 \
        --json experiments/disagg.json
"""

import argparse
import json
import os

from repro.configs.base import get_config
from repro.serving import device_model as dm
from repro.serving.disagg import run_disagg_serving
from repro.serving.token_engine import run_token_serving
from repro.serving.workload import long_prefill_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--prefill-mean", type=int, default=2048)
    ap.add_argument("--kv-budget", type=int, default=2048)
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--pool", type=int, default=3,
                    help="prefill-pool members (disagg mode)")
    ap.add_argument("--chunk", type=int, default=512,
                    help="chunk token budget (chunked mode)")
    ap.add_argument("--ttft-slo", type=float, default=1.2)
    ap.add_argument("--tpot-slo", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()

    prof = dm.llm_profile(get_config(args.config), mode="decode",
                          kv_seq_budget=args.kv_budget)
    trace = long_prefill_trace(args.requests, args.seed,
                               rate_rps=args.rate,
                               prefill_mean=args.prefill_mean)
    kw = dict(seed=args.seed, trace=trace, max_slots=args.slots,
              ttft_slo_s=args.ttft_slo, tpot_slo_s=args.tpot_slo)

    reports = {}
    for mode in ("cotenant", "chunked", "static"):
        if mode == "static":
            rep = run_token_serving(prof, policy="static",
                                    static_bs=args.slots, **kw)
        else:
            rep = run_token_serving(prof, policy="continuous",
                                    prefill_mode=mode,
                                    chunk_tokens=args.chunk, **kw)
        assert rep["conserved"], f"{mode}: conservation violated"
        reports[mode] = rep
    rep = run_disagg_serving(prof, n_prefill=args.pool, n_decode=1,
                             kv_seq_budget=args.kv_budget, **kw)
    assert rep["conserved"], "disagg: conservation violated"
    reports["disagg"] = rep

    print(f"{args.config} @ {args.rate:.0f} req/s, "
          f"{args.prefill_mean}-token-mean prompts, {args.slots} slots "
          f"(TTFT<={args.ttft_slo * 1e3:.0f}ms, "
          f"TPOT<={args.tpot_slo * 1e3:.0f}ms):\n")
    print(f"{'mode':<10} {'goodput':>12} {'ttft_p95':>9} {'ttft':>6} "
          f"{'tpot_p95':>9} {'tpot':>6} {'conserved':>9}")
    for mode, r in reports.items():
        print(f"{mode:<10} {r['goodput_tokens_s']:>8.1f}tok/s "
              f"{r['ttft_p95_s'] * 1e3:>7.0f}ms {r['ttft_attainment']:>6.3f} "
              f"{r['tpot_p95_s'] * 1e3:>7.2f}ms {r['tpot_attainment']:>6.3f} "
              f"{'yes' if r['conserved'] else 'NO':>9}")
    d, fab, pool = rep, rep["fabric"], rep["pool"]
    print(f"\ndisagg fleet: {args.pool} prefill + 1 decode device; "
          f"pool prefills {pool['prefills']}")
    print(f"KV fabric ({fab['interconnect']}, "
          f"{fab['bw_bps'] / 1e9:.0f} GB/s + "
          f"{fab['latency_s'] * 1e6:.0f} us/transfer): "
          f"{fab['bytes_moved'] / 1e9:.1f} GB in {fab['transfers']} "
          f"transfers, {fab['busy_s'] * 1e3:.0f} ms on the wire")
    best = max((r["goodput_tokens_s"], m) for m, r in reports.items()
               if m != "disagg")
    print(f"disagg vs best single-device mode ({best[1]}): "
          f"{d['goodput_tokens_s'] / max(best[0], 1e-9):.2f}x goodput")

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        # drop the raw per-request records: everything else is scalar
        jsonable = {m: {k: v for k, v in r.items() if k != "requests"}
                    for m, r in reports.items()}
        with open(args.json, "w") as f:
            json.dump(jsonable, f, indent=1)
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()
